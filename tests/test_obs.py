"""Unified observability layer (repro.obs, DESIGN.md §10): tracer spans
+ Chrome-trace schema, metrics registry + sinks, per-request timeline
reconstruction (preempt/resume edges, crash-replay dedup), and the
zero-cost-when-disabled guarantees — serve tokens and packed-ckpt bytes
must be bit-identical with and without instrumentation attached."""
import json

import jax
import numpy as np
import pytest

from repro.ckpt import pack_tree, save_packed_ckpt
from repro.configs import get_smoke_config
from repro.core import QuantSpec, quantize_model
from repro.ft.watchdog import Heartbeat
from repro.models import BuildPlan, init_params
from repro.obs import (NULL_METRICS, NULL_TRACER, MetricsRegistry, Tracer,
                       dedup_events, next_trace_path, reconstruct_timelines,
                       validate_timeline, validate_trace,
                       validate_trace_file)
from repro.obs import report as obs_report
from repro.obs import validate as obs_validate
from repro.serve import Runtime, ServeConfig

KEY = jax.random.PRNGKey(0)


def _f32_setup(arch="qwen2-7b"):
    cfg = get_smoke_config(arch).replace(compute_dtype="float32")
    plan = BuildPlan(remat=False, cache_dtype=jax.numpy.float32)
    params = init_params(KEY, cfg, plan)
    return cfg, plan, params


# ---------------------------------------------------------------------------
# tracer: span nesting + Chrome-trace schema
# ---------------------------------------------------------------------------

def test_span_nesting_and_chrome_trace_schema(tmp_path):
    tr = Tracer(run="unit")
    with tr.span("outer", layer=3) as outer:
        assert outer.elapsed_s >= 0.0
        with tr.span("inner", leaf="wq", device=True):
            pass
        tr.instant("note", k=1)
    tr.request_event("submit", 7, prompt_len=5)
    tr.token_event(7, 0, 42, 1234.5)

    evs = tr.events
    by_name = {e["name"]: e for e in evs}
    # inner closes before outer, so it lands first; both are "X" spans
    # with the inner interval contained in the outer one (same tid lane)
    inner, outer = by_name["inner"], by_name["outer"]
    assert inner["ph"] == outer["ph"] == "X" and inner["cat"] == "span"
    assert inner["tid"] == outer["tid"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0
    assert outer["args"] == {"layer": 3}
    # instants carry category + scope; token_event uses the caller's ts
    assert by_name["note"]["cat"] == "instant"
    assert by_name["submit"]["cat"] == "request"
    assert by_name["submit"]["args"]["rid"] == 7
    tok = by_name["token"]
    assert tok["cat"] == "request" and tok["ts"] == 1234.5
    assert tok["args"] == {"rid": 7, "i": 0, "token": 42}

    assert validate_trace(tr.to_chrome_trace()) == []
    path = next_trace_path(str(tmp_path), "unit")
    assert path.endswith("unit.g0.trace.json")
    tr.save(path)
    assert validate_trace_file(path) == []
    # a second generation gets a distinct filename
    assert next_trace_path(str(tmp_path), "unit").endswith("unit.g1.trace.json")


def test_validate_trace_rejects_malformed():
    assert validate_trace([]) != []
    assert validate_trace({"traceEvents": [{"name": "x"}]}) != []
    bad = {"traceEvents": [{"name": "x", "ph": "X", "ts": 0.0,
                            "pid": 1, "tid": 1, "dur": -1.0}]}
    assert any("dur" in p for p in validate_trace(bad))


def test_null_singletons_are_inert():
    assert NULL_TRACER.enabled is False and NULL_METRICS.enabled is False
    with NULL_TRACER.span("x", device=True) as s:
        assert s is NULL_TRACER.span("y")      # one shared no-op span
    assert NULL_TRACER.request_event("submit", 0) is None
    assert NULL_TRACER.token_event(0, 0, 0, 0.0) is None
    c = NULL_METRICS.counter("a")
    assert c is NULL_METRICS.histogram("b")    # one shared instrument
    c.inc()
    c.observe(3.0)
    assert c.value == 0.0 and c.count == 0
    assert NULL_METRICS.snapshot() == {}


# ---------------------------------------------------------------------------
# metrics: quantiles + sinks
# ---------------------------------------------------------------------------

def test_histogram_quantile_matches_numpy():
    rs = np.random.RandomState(3)
    vals = rs.randn(101).tolist()
    reg = MetricsRegistry(run="unit")
    h = reg.histogram("itl")
    for v in vals:
        h.observe(v)
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        assert h.quantile(q) == float(np.percentile(vals, q * 100.0))
    one = reg.histogram("one")
    one.observe(2.5)
    assert one.quantile(0.99) == 2.5
    assert np.isnan(reg.histogram("empty").quantile(0.5))


def test_metrics_sinks_roundtrip(tmp_path):
    reg = MetricsRegistry(run="unit")
    reg.counter("serve.tokens").inc(5)
    reg.gauge("pool.free").set(8.0)
    h = reg.histogram("serve.itl_seconds")
    for v in (0.001, 0.002, 0.4):
        h.observe(v)

    jpath = str(tmp_path / "metrics.jsonl")
    reg.dump_jsonl(jpath)
    recs = {r["name"]: r for r in
            (json.loads(ln) for ln in open(jpath) if ln.strip())}
    assert recs["serve.tokens"] == {"name": "serve.tokens",
                                    "kind": "counter", "run": "unit",
                                    "value": 5.0}
    assert recs["pool.free"]["value"] == 8.0
    # histograms carry the raw values so any quantile recomputes exactly
    assert recs["serve.itl_seconds"]["values"] == [0.001, 0.002, 0.4]
    assert recs["serve.itl_seconds"]["count"] == 3

    ppath = str(tmp_path / "metrics.prom")
    reg.dump_prometheus(ppath)
    prom = open(ppath).read()
    assert "# TYPE serve_tokens counter" in prom
    assert "serve_tokens 5.0" in prom
    assert "# TYPE serve_itl_seconds histogram" in prom
    assert 'serve_itl_seconds_bucket{le="0.0025"} 2' in prom
    assert 'serve_itl_seconds_bucket{le="+Inf"} 3' in prom
    assert "serve_itl_seconds_count 3" in prom

    snap = reg.snapshot()
    assert snap["serve.tokens"] == 5.0
    assert snap["serve.itl_seconds"]["count"] == 3
    assert snap["serve.itl_seconds"]["p50"] == 0.002


# ---------------------------------------------------------------------------
# timelines: crash-replay dedup (synthetic event streams)
# ---------------------------------------------------------------------------

def _rev(name, ts, **args):
    return {"name": name, "ph": "i", "cat": "request", "s": "t",
            "ts": float(ts), "pid": 1, "tid": 1, "args": args}


def test_timeline_crash_replay_rid_dedup():
    """Two restart generations of one request: the replay re-emits
    submit/first_token and the already-delivered token prefix; dedup
    keeps the first occurrence of each (token events by (rid, i)) while
    genuinely-new events (the resume admit, token i=2, retire) land."""
    gen0 = [
        _rev("submit", 1, rid=0, prompt_len=4, max_new_tokens=3, priority=0),
        _rev("admit", 2, rid=0, slot=0, resumed=False, prefill_len=4),
        _rev("first_token", 3, rid=0, token=7),
        _rev("token", 3, rid=0, i=0, token=7),
        _rev("token", 4, rid=0, i=1, token=8),
        _rev("preempt", 5, rid=0, n_preempts=1),
        # exact duplicate admit (torn journal flush) collapses too
        _rev("admit", 2, rid=0, slot=0, resumed=False, prefill_len=4),
    ]
    gen1 = [       # crash-replay generation: re-delivers the prefix
        _rev("submit", 11, rid=0, prompt_len=4, max_new_tokens=3, priority=0),
        _rev("admit", 12, rid=0, slot=1, resumed=True, prefill_len=8),
        _rev("first_token", 12, rid=0, token=7),
        _rev("token", 12, rid=0, i=0, token=7),
        _rev("token", 13, rid=0, i=1, token=8),
        _rev("token", 14, rid=0, i=2, token=9),
        _rev("retire", 15, rid=0, reason="length", new_tokens=3),
    ]
    merged = gen0 + gen1
    deduped = dedup_events(merged)
    assert sum(e["name"] == "token" for e in deduped) == 3
    assert sum(e["name"] == "submit" for e in deduped) == 1
    assert sum(e["name"] == "admit" for e in deduped) == 2

    tls = reconstruct_timelines(merged)
    assert set(tls) == {0}
    tl = tls[0]
    assert tl.t_submit == 1.0 and tl.t_first_token == 3.0
    assert tl.t_retire == 15.0 and tl.new_tokens == 3
    assert tl.tokens == [(0, 7), (1, 8), (2, 9)]
    assert tl.preempts == [5.0] and tl.resumes == [12.0]
    assert len(tl.admits) == 2
    assert tl.complete and validate_timeline(tl) == []
    assert tl.ttft_s == pytest.approx(2.0 / 1e6)
    assert tl.wall_s == pytest.approx(14.0 / 1e6)


def test_timeline_validation_flags_inconsistencies():
    # token count disagrees with the retire record
    evs = [_rev("submit", 1, rid=4, prompt_len=2),
           _rev("admit", 2, rid=4, slot=0, resumed=False, prefill_len=2),
           _rev("first_token", 3, rid=4, token=1),
           _rev("token", 3, rid=4, i=0, token=1),
           _rev("retire", 9, rid=4, reason="length", new_tokens=2)]
    tl = reconstruct_timelines(evs)[4]
    assert any("token events" in p for p in validate_timeline(tl))
    # never admitted
    tl2 = reconstruct_timelines([_rev("submit", 1, rid=5, prompt_len=2)])[5]
    assert any("never admitted" in p for p in validate_timeline(tl2))


# ---------------------------------------------------------------------------
# end to end: instrumented runtime under preemption, vs a plain one
# ---------------------------------------------------------------------------

def test_serve_obs_end_to_end_preempt_resume():
    """An over-subscribed instrumented run (a) emits bit-identical tokens
    to the uninstrumented runtime, (b) reconstructs a clean timeline for
    every request — at least one with preempt AND resume edges — whose
    token events equal the delivered stream, and (c) lands consistent
    registry counts."""
    cfg, plan, params = _f32_setup()
    rs = np.random.RandomState(7)
    prompts = [rs.randint(0, cfg.vocab_size, (l,)).astype(np.int32)
               for l in (14, 9, 12)]
    sc = ServeConfig(max_slots=3, block_size=8, num_blocks=6,
                     buckets=(8, 16, 32), max_blocks_per_slot=6)

    rt_plain = Runtime(params, cfg, plan, sc)
    assert rt_plain.tracer is NULL_TRACER and rt_plain.metrics is NULL_METRICS
    plain = rt_plain.generate([p for p in prompts], max_new_tokens=8)

    tr, reg = Tracer(run="test"), MetricsRegistry(run="test")
    rt = Runtime(params, cfg, plan, sc, tracer=tr, metrics=reg)
    reqs = [rt.submit(p, max_new_tokens=8) for p in prompts]
    rt.run()
    assert rt.scheduler.preemptions > 0

    for r, want in zip(reqs, plain):               # (a) bit-identity
        np.testing.assert_array_equal(np.asarray(r.out_tokens),
                                      np.asarray(want))

    assert validate_trace(tr.to_chrome_trace()) == []
    tls = reconstruct_timelines(tr.events)         # (b) timelines
    assert set(tls) == {r.rid for r in reqs}
    for r in reqs:
        tl = tls[r.rid]
        assert tl.complete and validate_timeline(tl) == []
        assert [t for _, t in tl.tokens] == [int(t) for t in r.out_tokens]
        assert tl.prompt_len == len(r.prompt)
        assert tl.finish_reason == r.finish_reason
    assert any(tls[r.rid].preempts and tls[r.rid].resumes for r in reqs)
    span_names = {e["name"] for e in tr.events if e["ph"] == "X"}
    assert {"decode_step", "serve.run"} <= span_names

    snap = reg.snapshot()                          # (c) metrics agree
    assert snap["serve.preemptions"] == rt.scheduler.preemptions
    assert snap["serve.tokens_emitted"] == sum(len(r.out_tokens)
                                               for r in reqs)
    assert snap["serve.requests_retired"] == len(reqs)
    assert snap["serve.ttft_seconds"]["count"] == len(reqs)
    assert snap["serve.resumes"] > 0
    # heartbeat snapshots embed the registry + runtime health dicts
    assert "live_occupancy" in rt.metrics_snapshot()


def test_disabled_tracer_quantize_bit_identical_packed_bytes(tmp_path):
    """quantize_model with a live tracer+registry must produce the same
    codes — packed-ckpt bytes compared — as an uninstrumented run; the
    tracer only *adds* span-derived wall_seconds to the layer reports."""
    cfg = get_smoke_config("qwen2-7b")
    plan = BuildPlan(remat=False)
    params = init_params(KEY, cfg, plan)
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    spec = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=1,
                     order="greedy")

    q_ref, rep_ref = quantize_model(params, cfg, plan, tokens, spec,
                                    method="comq_blocked")
    tr, reg = Tracer(run="q"), MetricsRegistry(run="q")
    q_obs, rep_obs = quantize_model(params, cfg, plan, tokens, spec,
                                    method="comq_blocked", tracer=tr,
                                    metrics=reg)

    def packed_bytes(q, path):
        host = jax.tree_util.tree_map(
            lambda a: np.asarray(jax.device_get(a))
            if isinstance(a, jax.Array) else a,
            pack_tree(q["__qlayers__"]))
        save_packed_ckpt(str(path), host)
        return open(path, "rb").read()

    assert packed_bytes(q_ref, tmp_path / "ref.qpk") == \
        packed_bytes(q_obs, tmp_path / "obs.qpk")

    rows = lambda rep: [(lr.layer, lr.name, lr.err_before, lr.err_after)
                        for lr in rep.layers]
    assert rows(rep_ref) == rows(rep_obs)
    # dispatch timing exists either way; true wall only with the tracer
    assert all(lr.wall_seconds == 0.0 for lr in rep_ref.layers)
    assert any(lr.wall_seconds > 0.0 for lr in rep_obs.layers)
    assert all(lr.seconds == lr.dispatch_seconds for lr in rep_obs.layers)

    assert {"layer", "leaf_solve"} <= {e["name"] for e in tr.events
                                       if e["ph"] == "X"}
    snap = reg.snapshot()
    assert snap["quant.leaves_solved"] > 0
    assert snap["quant.leaf_wall_seconds"]["count"] == \
        snap["quant.leaf_dispatch_seconds"]["count"]


# ---------------------------------------------------------------------------
# heartbeat snapshots + CLIs
# ---------------------------------------------------------------------------

def test_heartbeat_metrics_snapshot(tmp_path):
    hb = Heartbeat(str(tmp_path), host_id=0)
    hb.beat(3)
    rec = json.load(open(hb.path))
    assert rec["step"] == 3 and "metrics" not in rec

    reg = MetricsRegistry(run="hb")
    reg.counter("quant.layers_done").inc(4)
    hb.beat(4, metrics=reg.snapshot())
    rec = json.load(open(hb.path))
    assert rec["metrics"]["quant.layers_done"] == 4.0
    alive = Heartbeat.alive_hosts(str(tmp_path))
    assert alive[0]["metrics"]["quant.layers_done"] == 4.0


def _synthetic_run_dir(tmp_path):
    tr = Tracer(run="synthetic")
    with tr.span("decode_step", step=0):
        pass
    for e in [_rev("submit", 1, rid=0, prompt_len=4, max_new_tokens=1),
              _rev("admit", 2, rid=0, slot=0, resumed=False, prefill_len=4),
              _rev("first_token", 3, rid=0, token=7),
              _rev("token", 3, rid=0, i=0, token=7),
              _rev("retire", 4, rid=0, reason="length", new_tokens=1)]:
        tr._events.append(("i", e["name"], "request", e["ts"], 1, e["args"]))
    tr.save(next_trace_path(str(tmp_path), "serve"))
    reg = MetricsRegistry(run="synthetic")
    reg.counter("serve.tokens_emitted").inc()
    reg.histogram("serve.itl_seconds").observe(0.01)
    reg.dump_jsonl(str(tmp_path / "metrics.jsonl"))
    return tmp_path


def test_report_cli_smoke(tmp_path, capsys):
    run_dir = _synthetic_run_dir(tmp_path)
    assert obs_report.main([str(run_dir)]) == 0
    out = capsys.readouterr().out
    assert "== spans ==" in out and "decode_step" in out
    assert "== requests ==" in out and "== metrics ==" in out
    empty = tmp_path / "empty"
    empty.mkdir()
    assert obs_report.main([str(empty)]) == 1


def test_validate_cli_timelines(tmp_path, capsys):
    run_dir = _synthetic_run_dir(tmp_path)
    trace = str(run_dir / "serve.g0.trace.json")
    assert obs_validate.main(["--timelines", trace]) == 0
    # the synthetic request never preempts, so --require-preempt fails
    assert obs_validate.main(["--timelines", "--require-preempt",
                              trace]) == 1
    capsys.readouterr()
