"""Serving engine + quantized serving paths (QT weights, int8 KV cache)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.apply import (QT, dequantize_qt_tree, fake_quantize_params,
                              is_qt)
from repro.models import (BuildPlan, decode_step, forward, init_params,
                          prefill)
from repro.serve.engine import Engine
from repro.serve.sampler import sample

KEY = jax.random.PRNGKey(0)


def test_engine_greedy_matches_forward_argmax():
    cfg = get_smoke_config("qwen2-7b").replace(compute_dtype="float32")
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32)
    params = init_params(KEY, cfg, plan)
    prompts = np.asarray(jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size))
    eng = Engine(params, cfg, plan, max_len=24)
    out = eng.generate_batch(prompts, max_new_tokens=1)
    logits, _, _ = forward(params, cfg, plan, jnp.asarray(prompts))
    want = np.asarray(jnp.argmax(logits[:, -1], -1))
    np.testing.assert_array_equal(out[:, 0], want)


def test_sampler_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    assert int(sample(logits, KEY, temperature=0.0)[0]) == 1
    s = sample(jnp.tile(logits, (64, 1)), KEY, temperature=1.0, top_k=2)
    assert set(np.asarray(s).tolist()) <= {1, 2}


def test_sampler_top_p_restricts_support():
    # token 1 carries ~98% of the mass: top_p=0.5 keeps only token 1
    logits = jnp.tile(jnp.asarray([[0.0, 5.0, 1.0]]), (512, 1))
    s = sample(logits, KEY, temperature=1.0, top_p=0.5)
    assert set(np.asarray(s).tolist()) == {1}
    # near-flat logits with top_p=0.6: exactly the two most likely survive
    logits2 = jnp.tile(jnp.asarray([[2.0, 2.1, 1.9, -5.0]]), (512, 1))
    s = sample(logits2, KEY, temperature=1.0, top_p=0.6)
    assert set(np.asarray(s).tolist()) == {0, 1}


def test_sampler_seeded_determinism():
    from repro.serve.sampler import sample_batch
    logits = jax.random.normal(jax.random.PRNGKey(3), (8, 32))
    temp = jnp.asarray([0.0, 1.0, 0.7, 1.3, 0.0, 1.0, 1.0, 0.5])
    top_k = jnp.asarray([0, 5, 0, 3, 0, 0, 8, 0], jnp.int32)
    top_p = jnp.asarray([0.0, 0.0, 0.9, 0.5, 0.0, 0.3, 0.0, 0.95])
    a = np.asarray(sample_batch(logits, KEY, temperature=temp, top_k=top_k,
                                top_p=top_p))
    b = np.asarray(sample_batch(logits, KEY, temperature=temp, top_k=top_k,
                                top_p=top_p))
    np.testing.assert_array_equal(a, b)          # same seed -> same draw
    c = np.asarray(sample_batch(logits, jax.random.PRNGKey(9),
                                temperature=temp, top_k=top_k, top_p=top_p))
    assert (a != c).any()                        # seed actually matters
    # greedy rows ignore the rng entirely
    greedy = np.asarray(jnp.argmax(logits, -1))
    for row in (0, 4):
        assert a[row] == greedy[row] == c[row]


def test_sample_batch_per_slot_filters():
    from repro.serve.sampler import sample_batch
    logits = jnp.tile(jnp.asarray([[0.0, 5.0, 1.0, 4.0]]), (256, 1))
    temp = jnp.ones((256,))
    # top_k=2 keeps {1, 3}; top_p tiny keeps only argmax {1}
    ks = jax.random.split(KEY, 2)
    s_k = np.asarray(sample_batch(logits, ks[0], temperature=temp,
                                  top_k=jnp.full((256,), 2, jnp.int32),
                                  top_p=jnp.zeros((256,))))
    assert set(s_k.tolist()) <= {1, 3}
    s_p = np.asarray(sample_batch(logits, ks[1], temperature=temp,
                                  top_k=jnp.zeros((256,), jnp.int32),
                                  top_p=jnp.full((256,), 0.05)))
    assert set(s_p.tolist()) == {1}


@pytest.mark.parametrize("bits", [8, 4])
def test_qt_weights_exact_vs_dense_dequant(bits):
    cfg = get_smoke_config("mistral-large-123b").replace(
        compute_dtype="float32")
    plan = BuildPlan(remat=False, cache_dtype=jnp.float32,
                     prefill_cache_len=40)
    params = init_params(KEY, cfg, plan)
    qparams = fake_quantize_params(params, cfg, plan, bits=bits)
    dense = jax.tree_util.tree_map(
        lambda x: x.dequant(jnp.float32) if is_qt(x) else x, qparams,
        is_leaf=is_qt)
    tokens = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)
    lq, cq = prefill(qparams, cfg, plan, tokens)
    ld, cd = prefill(dense, cfg, plan, tokens)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(ld), atol=1e-5)
    gq, _ = decode_step(qparams, cfg, plan, cq, tokens[:, :1], jnp.int32(24))
    gd, _ = decode_step(dense, cfg, plan, cd, tokens[:, :1], jnp.int32(24))
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gd), atol=1e-5)


def test_int8_kv_cache_close_to_dense():
    cfg = get_smoke_config("deepseek-67b").replace(compute_dtype="float32")
    plan_fp = BuildPlan(remat=False, cache_dtype=jnp.float32,
                        prefill_cache_len=40)
    plan_q8 = plan_fp.replace(cache_quant=True)
    params = init_params(KEY, cfg, plan_fp)
    tokens = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)
    l_fp, c_fp = prefill(params, cfg, plan_fp, tokens)
    l_q8, c_q8 = prefill(params, cfg, plan_q8, tokens)
    assert c_q8["kv"].k.dtype == jnp.int8
    # int8 cache: small relative error on logits, identical argmax mostly
    denom = float(jnp.max(jnp.abs(l_fp))) + 1e-9
    rel = float(jnp.max(jnp.abs(l_q8 - l_fp))) / denom
    assert rel < 0.08, rel
    g_fp, _ = decode_step(params, cfg, plan_fp, c_fp, tokens[:, :1],
                          jnp.int32(24))
    g_q8, _ = decode_step(params, cfg, plan_q8, c_q8, tokens[:, :1],
                          jnp.int32(24))
    agree = float((jnp.argmax(g_fp, -1) == jnp.argmax(g_q8, -1)).mean())
    assert agree >= 0.5
