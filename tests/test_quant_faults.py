"""Crash-safe resumable quantization (DESIGN.md §8.1): journaled runs,
kill-injected resume bit-identity (the test oracle: a resumed run must be
bit-identical to an uninterrupted one, down to the packed-checkpoint
bytes), journal↔spill integrity, and supervised self-recovery through
ft.run_with_restarts — mirroring launch/quantize.py --journal/--restarts.
"""
import glob
import os

import jax
import numpy as np
import pytest

from repro.ckpt import PackedCkptError, pack_tree, save_packed_ckpt
from repro.configs import get_smoke_config
from repro.core import QuantSpec, parse_policy, quantize_model
from repro.ft import (FaultInjector, InjectedFault, QuantJournal,
                      ResumeMismatch, SimulatedKill, run_with_restarts)
from repro.models import BuildPlan, init_params

PLAN = BuildPlan(remat=False)
KEY = jax.random.PRNGKey(0)
SPEC = QuantSpec(bits=4, granularity="per_channel", lam=0.9, sweeps=1,
                 order="greedy")


def _setup(arch="qwen2-7b"):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg, PLAN)
    tokens = jax.random.randint(KEY, (4, 64), 0, cfg.vocab_size)
    return cfg, params, tokens


def _assert_trees_identical(a, b):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        xa = np.asarray(jax.device_get(x))
        ya = np.asarray(jax.device_get(y))
        assert xa.dtype == ya.dtype
        assert np.array_equal(xa, ya)


def _packed_bytes(qparams, path):
    host = jax.tree_util.tree_map(
        lambda a: np.asarray(jax.device_get(a))
        if isinstance(a, jax.Array) else a, pack_tree(qparams["__qlayers__"]))
    save_packed_ckpt(path, host)
    with open(path, "rb") as f:
        return f.read()


def _report_rows(report):
    # seconds is wall time (0.0 for re-applied leaves) — exclude it
    return [(lr.layer, lr.name, lr.err_before, lr.err_after)
            for lr in report.layers]


def test_kill_resume_bit_identical_dense(tmp_path):
    """The core oracle: kill mid-run, resume from the journal, and get
    codes/scales, per-leaf reported errors, AND packed-checkpoint bytes
    identical to an uninterrupted run."""
    cfg, params, tokens = _setup()
    ref_q, ref_rep = quantize_model(params, cfg, PLAN, tokens, SPEC,
                                    method="comq_blocked")
    jd = str(tmp_path / "journal")
    inj = FaultInjector({"kill": [2]})
    with pytest.raises(SimulatedKill):
        quantize_model(params, cfg, PLAN, tokens, SPEC,
                       method="comq_blocked", journal=jd, injector=inj)
    st = QuantJournal.replay(jd)
    assert st.leaves and not st.done
    assert QuantJournal.check_integrity(jd) == len(st.leaves)

    qp, rep = quantize_model(params, cfg, PLAN, tokens, SPEC,
                             method="comq_blocked", journal=jd, resume=True,
                             injector=inj)
    assert rep.resumed_leaves == len(st.leaves)
    assert QuantJournal.replay(jd).done
    _assert_trees_identical(ref_q["__qlayers__"], qp["__qlayers__"])
    assert _report_rows(rep) == _report_rows(ref_rep)
    assert _packed_bytes(ref_q, str(tmp_path / "ref.qpk")) == \
        _packed_bytes(qp, str(tmp_path / "res.qpk"))


def test_kill_resume_bit_identical_moe_mixed_policy(tmp_path):
    """Same oracle on the MoE smoke arch (vmapped stacked-expert solves)
    under a mixed-precision policy (per-leaf resolved specs)."""
    cfg, params, tokens = _setup("granite-moe-3b-a800m")
    policy = parse_policy("first=8", SPEC)
    ref_q, ref_rep = quantize_model(params, cfg, PLAN, tokens, policy,
                                    method="comq_blocked")
    jd = str(tmp_path / "journal")
    inj = FaultInjector({"kill": [1]})
    with pytest.raises(SimulatedKill):
        quantize_model(params, cfg, PLAN, tokens, policy,
                       method="comq_blocked", journal=jd, injector=inj)
    st = QuantJournal.replay(jd)
    assert st.leaves and not st.done

    qp, rep = quantize_model(params, cfg, PLAN, tokens, policy,
                             method="comq_blocked", journal=jd, resume=True,
                             injector=inj)
    assert rep.resumed_leaves == len(st.leaves)
    _assert_trees_identical(ref_q["__qlayers__"], qp["__qlayers__"])
    assert _report_rows(rep) == _report_rows(ref_rep)


def test_resume_digest_mismatch_raises(tmp_path):
    """A journal written under one resolved policy must refuse to resume
    a run with a different one (stale journals produce silent garbage)."""
    cfg, params, tokens = _setup()
    jd = str(tmp_path / "journal")
    inj = FaultInjector({"kill": [1]})
    with pytest.raises(SimulatedKill):
        quantize_model(params, cfg, PLAN, tokens, SPEC,
                       method="comq_blocked", journal=jd, injector=inj)
    other = QuantSpec(bits=3, granularity="per_channel", lam=0.9, sweeps=1,
                      order="greedy")
    with pytest.raises(ResumeMismatch):
        quantize_model(params, cfg, PLAN, tokens, other,
                       method="comq_blocked", journal=jd, resume=True)
    # a different method over the same spec must mismatch too
    with pytest.raises(ResumeMismatch):
        quantize_model(params, cfg, PLAN, tokens, SPEC, method="rtn",
                       journal=jd, resume=True)


def test_ckpt_write_fault_never_journals_torn_leaf(tmp_path):
    """A crash between the durable spill-tmp write and its rename (the
    torn-write window) must leave the journal without a record for that
    leaf: the tmp file lingers, the target doesn't exist, integrity
    passes, and the resumed run re-solves it bit-identically."""
    cfg, params, tokens = _setup()
    ref_q, _ = quantize_model(params, cfg, PLAN, tokens, SPEC,
                              method="comq_blocked")
    jd = str(tmp_path / "journal")
    inj = FaultInjector({"ckpt_write": [1]})
    with pytest.raises(InjectedFault):
        quantize_model(params, cfg, PLAN, tokens, SPEC,
                       method="comq_blocked", journal=jd, injector=inj)
    st = QuantJournal.replay(jd)
    spill = os.path.join(jd, "leaves")
    torn = glob.glob(os.path.join(spill, "*.tmp"))
    assert torn, "the injected torn write should leave a .tmp behind"
    for t in torn:
        assert not os.path.exists(t[:-len(".tmp")])
        assert os.path.basename(t)[:-len(".tmp")] not in {
            rec["file"] for rec in st.leaves.values()}
    QuantJournal.check_integrity(jd)   # journaled leaves all load

    qp, _ = quantize_model(params, cfg, PLAN, tokens, SPEC,
                           method="comq_blocked", journal=jd, resume=True,
                           injector=inj)
    _assert_trees_identical(ref_q["__qlayers__"], qp["__qlayers__"])


def test_integrity_check_detects_corrupt_spill(tmp_path):
    """Flipping one byte of a journaled spill must fail the journal↔
    checkpoint integrity check (payload crc32 + journaled crc)."""
    cfg, params, tokens = _setup()
    jd = str(tmp_path / "journal")
    inj = FaultInjector({"kill": [1]})
    with pytest.raises(SimulatedKill):
        quantize_model(params, cfg, PLAN, tokens, SPEC,
                       method="comq_blocked", journal=jd, injector=inj)
    st = QuantJournal.replay(jd)
    rec = next(iter(st.leaves.values()))
    path = os.path.join(jd, "leaves", rec["file"])
    blob = bytearray(open(path, "rb").read())
    blob[-1] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(PackedCkptError):
        QuantJournal.check_integrity(jd)
    # a missing spill is the same failure class
    os.remove(path)
    with pytest.raises(PackedCkptError):
        QuantJournal.check_integrity(jd)


def test_supervised_restarts_recover_multiple_faults(tmp_path):
    """The launcher's supervision loop: run_with_restarts + journal
    progress signal self-recovers through a kill, a Gram-accumulation
    fault, and a leaf-solve fault, converging to a complete run whose
    packed bytes match the clean run's."""
    cfg, params, tokens = _setup()
    ref_q, _ = quantize_model(params, cfg, PLAN, tokens, SPEC,
                              method="comq_blocked")
    jd = str(tmp_path / "journal")
    inj = FaultInjector({"kill": [1], "gram_accumulate": [6],
                         "leaf_solve": [9]})
    box = {}

    def attempt(_):
        resume = bool(QuantJournal.replay(jd).leaves)
        if resume:
            QuantJournal.check_integrity(jd)
        box["out"] = quantize_model(params, cfg, PLAN, tokens, SPEC,
                                    method="comq_blocked", journal=jd,
                                    resume=resume, injector=inj)

    def progress():
        return len(QuantJournal.replay(jd).leaves)

    run_with_restarts(attempt, progress, max_restarts=3,
                      exceptions=(RuntimeError,), backoff_s=0.0)
    qp, rep = box["out"]
    assert len(inj.fired) == 3
    assert QuantJournal.replay(jd).done
    assert rep.resumed_leaves > 0
    _assert_trees_identical(ref_q["__qlayers__"], qp["__qlayers__"])
    assert _packed_bytes(ref_q, str(tmp_path / "ref.qpk")) == \
        _packed_bytes(qp, str(tmp_path / "sup.qpk"))


def test_journaling_alone_changes_nothing(tmp_path):
    """A healthy journaled run is bit-identical to a plain one (the
    journal only adds host syncs), and a completed journal resumes to
    a full re-application (zero re-solves)."""
    cfg, params, tokens = _setup()
    ref_q, _ = quantize_model(params, cfg, PLAN, tokens, SPEC,
                              method="comq_blocked")
    jd = str(tmp_path / "journal")
    q1, rep1 = quantize_model(params, cfg, PLAN, tokens, SPEC,
                              method="comq_blocked", journal=jd)
    assert rep1.resumed_leaves == 0
    _assert_trees_identical(ref_q["__qlayers__"], q1["__qlayers__"])
    q2, rep2 = quantize_model(params, cfg, PLAN, tokens, SPEC,
                              method="comq_blocked", journal=jd, resume=True)
    assert rep2.resumed_leaves == len(rep2.layers)
    _assert_trees_identical(ref_q["__qlayers__"], q2["__qlayers__"])


def test_injector_rejects_unknown_pipeline_point():
    with pytest.raises(ValueError):
        FaultInjector.parse("gram_acumulate:1")
