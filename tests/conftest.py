import os
import sys

# tests are documented to run as `PYTHONPATH=src pytest tests/`; make the
# import work regardless of invocation directory.
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if _SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(_SRC))

# NOTE: do NOT set XLA_FLAGS here — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py forces 512 host devices
# (multi-device tests spawn subprocesses instead).
