"""Data pipeline: determinism, structure (learnability signal), resume."""
import numpy as np

from repro.data import ShardedLoader, SyntheticLM, batches


def test_deterministic_and_resumable():
    g1 = batches(1000, 4, 32, seed=0)
    g2 = batches(1000, 4, 32, seed=0)
    b1, b2 = next(g1), next(g2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # resume from step 3 reproduces the 4th batch (g1 already consumed b1)
    g3 = batches(1000, 4, 32, seed=0, start_step=3)
    for _ in range(2):
        next(g1)
    np.testing.assert_array_equal(next(g1)["tokens"], next(g3)["tokens"])


def test_labels_are_next_tokens():
    b = SyntheticLM(500, seed=1).sample(2, 16)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    # stream has Markov structure: many labels equal token + topic offset
    diffs = (b["labels"] - b["tokens"]) % 500
    common = np.bincount(diffs.ravel()).max() / diffs.size
    assert common > 0.3


def test_sharded_loader_prefetch_and_state():
    loader = ShardedLoader(1000, 8, 16, seed=0)
    b1 = next(loader)
    assert b1["tokens"].shape == (8, 16)
    st = loader.state()
    assert st["step"] >= 1
    loader.close()
